"""Clause-skip execution (ISSUE 5 acceptance).

The Alg-6 compacted TA-update datapath must be a pure wall-clock
optimisation — never a semantic one:

* ops-level: ``ta_update_compact_op`` == ``ta_update_op(emit_include=True)``
  bit-for-bit on BOTH backends (jnp ref + interpret-mode Pallas sparse
  kernel), under random feedback masks (hypothesis property when
  available + a deterministic sweep), on remainder shapes, at every
  capacity-bucket boundary (n_active == cap and cap + 1), and at row /
  tile compaction granularities;
* engine-level: training with ``REPRO_SKIP=1`` (compact) vs
  ``REPRO_SKIP=0`` (dense-forced) produces bit-identical programs,
  histories, and stats for all FIVE TMSpec kinds on both backends — this
  file runs under both ``REPRO_KERNEL_PATH`` CI legs like the rest of the
  suite;
* session-level: the in-trace capacity switch keeps the device-resident
  epoch scan at ≤ 1 dispatch per epoch (``session.dispatches`` probe),
  and program banks fall back to the dense update (vmap would otherwise
  execute every bucket per lane);
* observability: ``path_per_stage`` records the SKIP dimension
  (``train_ta`` = compact/dense) and ``TMServer.stats()`` surfaces the
  per-tenant lifetime ``skip_frac``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import TMSpec
from repro.core import PRNG
from repro.kernels import (ops as kops, ref, resolve_skip, select_ta_path,
                           ta_update_compact_op, ta_update_op)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # bare tier-1 env
    hypothesis = None

_rng = np.random.default_rng(7)
_CALIB = _rng.standard_normal((64, 8)).astype(np.float32)

SPECS = {
    "cotm": TMSpec.coalesced(features=20, classes=3, clauses=24, T=8, s=3.0),
    "vanilla": TMSpec.vanilla(features=16, classes=4, clauses=8, T=8, s=3.0),
    "conv": TMSpec.conv(img_h=6, img_w=6, patch=3, classes=2, clauses=16,
                        T=8, s=3.0),
    "regression": TMSpec.regression(features=12, clauses=16, T=16, s=3.0),
    "head": TMSpec.head(_CALIB, classes=3, therm_bits=2, clauses=16, T=8,
                        s=3.0),
}


# ---------------------------------------------------------------------------
# ops-level bit-identity: compact == dense
# ---------------------------------------------------------------------------

def _inputs(C, L, B, active_rows, seed=0, n_states=256):
    rng = np.random.default_rng(seed)
    ta = jnp.asarray(rng.integers(0, n_states, (C, L)), jnp.int32)
    lit = jnp.asarray(rng.integers(0, 2, (B, L)), jnp.int8)
    cl = jnp.asarray(rng.integers(0, 2, (B, C)), jnp.int8)
    t1 = jnp.asarray(rng.integers(0, 2, (B, C)) * active_rows[None, :],
                     jnp.int8)
    t2 = jnp.asarray(rng.integers(0, 2, (B, C)) * active_rows[None, :],
                     jnp.int8)
    lm = jnp.asarray(rng.integers(0, 2, (L,)), jnp.int32)
    inc = ref.pack_include(ta, n_states)
    return ta, lit, cl, t1, t2, lm, inc


def _assert_compact_equals_dense(C, L, B, active_rows, backend, group,
                                 seed=0, n_states=256):
    ta, lit, cl, t1, t2, lm, inc = _inputs(C, L, B, active_rows, seed,
                                           n_states)
    s, p = jnp.uint32(seed * 77 + 5), jnp.uint32(16000)
    d_ta, d_inc = ta_update_op(ta, lit, cl, t1, t2, lm, s, p,
                               backend=backend, emit_include=True,
                               n_states=n_states)
    c_ta, c_inc = ta_update_compact_op(ta, lit, cl, t1, t2, lm, inc, s, p,
                                       backend=backend, group=group,
                                       n_states=n_states)
    np.testing.assert_array_equal(np.asarray(d_ta), np.asarray(c_ta))
    np.testing.assert_array_equal(np.asarray(d_inc), np.asarray(c_inc))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("shape", [(256, 512, 4), (200, 300, 3),
                                   (64, 40, 2)])
def test_compact_matches_dense_sweep(backend, shape):
    """Deterministic sweep: both backends, remainder shapes, activity from
    empty to full."""
    C, L, B = shape
    rng = np.random.default_rng(C)
    for frac in (0.0, 0.05, 0.3, 1.0):
        act = (rng.random(C) < frac).astype(np.int8)
        _assert_compact_equals_dense(C, L, B, act, backend,
                                     group=1 if backend == "ref" else 32,
                                     seed=int(frac * 10))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_capacity_bucket_boundaries(backend):
    """n_active exactly AT each capacity bucket and one past it (the
    lax.switch branch edges), including the dense-fallback edge."""
    C, L, B, group = 256, 128, 2, (1 if backend == "ref" else 128)
    n_groups = -(-C // group)
    caps = kops._skip_caps(n_groups)
    assert caps, (n_groups, group)
    for cap in caps:
        for n_act in (max(cap - 1, 0), cap, min(cap + 1, n_groups)):
            act = np.zeros(C, np.int8)
            # scatter the active groups non-contiguously
            gidx = np.linspace(0, n_groups - 1, max(n_act, 1),
                               dtype=int)[:n_act]
            for gi in gidx:
                act[gi * group:(gi + 1) * group] = 1
            _assert_compact_equals_dense(C, L, B, act, backend, group,
                                         seed=cap + n_act)


def test_compact_row_vs_tile_granularity_agree():
    """The compaction granularity is an execution detail: row-level (the
    engine's ref path) and coarse-group compaction produce the same
    bits."""
    C, L, B = 192, 96, 3
    rng = np.random.default_rng(0)
    act = (rng.random(C) < 0.1).astype(np.int8)
    for group in (1, 8, 32, 64):
        _assert_compact_equals_dense(C, L, B, act, "ref", group)


# ---------------------------------------------------------------------------
# PRNG stream invariants (ISSUE 8): the TA-update randoms are a pure
# function of (seed, element index, stream family) — execution layout
# (dense / compact / streamed / banked) must never change them
# ---------------------------------------------------------------------------

def test_lfsr_stream_period_and_refresh():
    """With refresh off, the L-bit LFSR lanes are maximal-length: the
    emitted stream repeats with period 2^L - 1.  With the paper's master-
    slave refresh on, the cycle AT the period boundary is re-seeded from
    the advanced master instead of repeating."""
    bits, C, L = 4, 2, 8
    period = (1 << bits) - 1
    free = np.asarray(ref.ta_rand_stream(5, 2 * period, C, L, prng="lfsr",
                                         lfsr_bits=bits, seed_refresh=False,
                                         xt=L))
    np.testing.assert_array_equal(free[:period], free[period:])
    rr = np.asarray(ref.ta_rand_stream(5, period, C, L, prng="lfsr",
                                       lfsr_bits=bits, seed_refresh=True,
                                       xt=L))
    np.testing.assert_array_equal(rr[:period - 1], free[:period - 1])
    assert (rr[period - 1] != free[period - 1]).any()


def test_bank_lanes_identical_streams_lfsr():
    """lanes > 1 banks fall back to the dense TA update — under the
    paper-faithful lfsr family each lane must still advance exactly the
    per-program stream, so bank training == sequential per-program
    training bit-for-bit."""
    import dataclasses
    spec = dataclasses.replace(SPECS["cotm"], prng_backend="lfsr")
    eng = api.compile(api.tile_for(spec, x=32, y=16, m=16, n=4))
    progs, prngs = [], []
    for i in range(3):
        progs.append(eng.lower(spec, jax.random.PRNGKey(i)))
        prngs.append(PRNG.create(spec.tm_config(), 10 + i))
    rng = np.random.default_rng(0)
    x = (rng.random((3, 8, spec.features)) < 0.5).astype(np.int8)
    y = rng.integers(0, spec.classes, (3, 8)).astype(np.int32)
    lits = jnp.stack([eng.encode(spec, jnp.asarray(x[k]))
                      for k in range(3)])
    bank = api.stack(progs, eng, prngs=prngs)
    bank.train(lits, jnp.asarray(y))
    for k in range(3):
        solo, _, _ = eng.train_step(progs[k], prngs[k], lits[k],
                                    jnp.asarray(y[k]))
        got = bank.swap_out(k)
        np.testing.assert_array_equal(np.asarray(got.ta),
                                      np.asarray(solo.ta), err_msg=str(k))


if hypothesis is not None:
    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_ta_stream_invariant_property(data):
        """Random shapes, seeds, families, refresh settings: the dense
        in-kernel stream == the Alg-6 compact path == the streamed
        [B, C, L] materialisation (ref backend; the Pallas legs are
        pinned by the deterministic sweeps in test_kernel_speed.py)."""
        C = data.draw(st.integers(2, 40), label="C")
        L = data.draw(st.integers(2, 64), label="L")
        B = data.draw(st.integers(1, 4), label="B")
        bits = data.draw(st.sampled_from((4, 8, 24)), label="bits")
        refresh = data.draw(st.booleans(), label="refresh")
        prng = data.draw(st.sampled_from(("counter", "lfsr")), label="prng")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        rng = np.random.default_rng(seed % 251)
        ta = jnp.asarray(rng.integers(0, 256, (C, L)), jnp.int32)
        lit = jnp.asarray(rng.integers(0, 2, (B, L)), jnp.int8)
        cl = jnp.asarray(rng.integers(0, 2, (B, C)), jnp.int8)
        t1 = jnp.asarray(rng.integers(0, 2, (B, C)), jnp.int8)
        t2 = jnp.asarray(rng.integers(0, 2, (B, C)), jnp.int8)
        lm = jnp.ones((L,), jnp.int32)
        inc = ref.pack_include(ta, 256)
        kw = dict(prng=prng, lfsr_bits=bits, seed_refresh=refresh,
                  backend="ref")
        dense = ta_update_op(ta, lit, cl, t1, t2, lm, seed, 9000, **kw)
        streamed = ta_update_op(ta, lit, cl, t1, t2, lm, seed, 9000,
                                stream=True, **kw)
        compact, _ = ta_update_compact_op(ta, lit, cl, t1, t2, lm, inc,
                                          seed, 9000, **kw)
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(streamed))
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(compact))

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def test_compact_matches_dense_property(data):
        """Random shapes, random (sparse to dense) feedback masks, random
        n_states — compact == dense bit-for-bit on the ref backend (the
        Pallas leg is pinned by the deterministic sweep; interpret-mode
        hypothesis sweeps are nightly-tier slow)."""
        C = data.draw(st.integers(2, 80), label="C")
        L = data.draw(st.integers(2, 70), label="L")
        B = data.draw(st.integers(1, 5), label="B")
        frac = data.draw(st.floats(0, 1), label="frac")
        group = data.draw(st.sampled_from((1, 4, 32)), label="group")
        n_states = data.draw(st.sampled_from((4, 256)), label="n_states")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        rng = np.random.default_rng(seed)
        act = (rng.random(C) < frac).astype(np.int8)
        _assert_compact_equals_dense(C, L, B, act, "ref", group,
                                     seed=seed % 97, n_states=n_states)


# ---------------------------------------------------------------------------
# engine-level: five kinds, skip on == skip off, both backends
# ---------------------------------------------------------------------------

def _train_once(kind, backend, skip, monkeypatch, epochs=2):
    monkeypatch.setenv("REPRO_SKIP", skip)
    spec = SPECS[kind]
    tm = api.TM(spec, seed=0, backend=backend)
    rng = np.random.default_rng(0)
    n = 48
    if kind == "conv":
        x = (rng.random((n, 6, 6)) < 0.4).astype(np.int8)
    elif kind == "head":
        x = rng.standard_normal((n, 8)).astype(np.float32)
    else:
        x = (rng.random((n, spec.features)) < 0.5).astype(np.int8)
    if kind == "regression":
        y = rng.random(n).astype(np.float32)
    else:
        y = rng.integers(0, spec.classes, n).astype(np.int32)
    hist = tm.fit(x, y, epochs=epochs, batch=8,
                  rng=np.random.default_rng(3))
    return tm, hist


@pytest.mark.parametrize("kind", sorted(SPECS))
def test_engine_skip_bit_identical_ref(kind, monkeypatch):
    tm1, h1 = _train_once(kind, "ref", "1", monkeypatch)
    tm0, h0 = _train_once(kind, "ref", "0", monkeypatch)
    assert h1 == h0
    for leaf1, leaf0 in zip(jax.tree.leaves(tm1.program),
                            jax.tree.leaves(tm0.program)):
        np.testing.assert_array_equal(np.asarray(leaf1), np.asarray(leaf0))
    if kind != "conv":      # conv's TA stage is the jnp conv-feedback path
        # the skip dimension is recorded (and differs between the runs)
        assert tm1.engine.cache_report()["path_per_stage"]["train_ta"] == \
            kops.TA_COMPACT
        assert tm0.engine.cache_report()["path_per_stage"]["train_ta"] == \
            kops.TA_DENSE
    # lifetime skip accounting agrees between the two execution modes
    assert tm1.skip_frac == tm0.skip_frac
    assert tm1.skip_frac is not None


@pytest.mark.slow
@pytest.mark.parametrize("kind", sorted(SPECS))
def test_engine_skip_bit_identical_kernel(kind, monkeypatch):
    """Same claim through the interpret-mode Pallas kernels (the sparse
    scalar-prefetch gather kernel on the compact branch)."""
    tm1, h1 = _train_once(kind, "kernel", "1", monkeypatch, epochs=1)
    tm0, h0 = _train_once(kind, "kernel", "0", monkeypatch, epochs=1)
    assert h1 == h0
    for leaf1, leaf0 in zip(jax.tree.leaves(tm1.program),
                            jax.tree.leaves(tm0.program)):
        np.testing.assert_array_equal(np.asarray(leaf1), np.asarray(leaf0))


# ---------------------------------------------------------------------------
# sessions, banks, serving
# ---------------------------------------------------------------------------

def test_session_dispatches_stay_one_per_epoch_with_skip(monkeypatch):
    """The capacity-bucket selection is IN-TRACE (lax.switch inside the
    epoch scan): skip execution must not add host round trips."""
    monkeypatch.setenv("REPRO_SKIP", "1")
    spec = SPECS["cotm"]
    tm = api.TM(spec, seed=0)
    rng = np.random.default_rng(0)
    x = (rng.random((64, spec.features)) < 0.5).astype(np.int8)
    y = rng.integers(0, spec.classes, 64).astype(np.int32)
    session = tm.engine.bind(tm.program, x, y, spec=spec, prng=tm.prng)
    epochs = 3
    session.fit_epochs(epochs, batch=8, rng=np.random.default_rng(1))
    assert session.dispatches == epochs
    assert tm.engine.cache_report()["path_per_stage"]["train_ta"] == \
        kops.TA_COMPACT
    report = tm.engine.cache_report()
    assert all(v <= 1 for v in report.values() if isinstance(v, int)), report


def test_bank_training_forces_dense(monkeypatch):
    """vmapped program banks must take the dense TA path (lanes > 1) —
    and still match per-program sequential training bit-for-bit."""
    monkeypatch.setenv("REPRO_SKIP", "1")
    spec = SPECS["cotm"]
    eng = api.compile(api.tile_for(spec, x=32, y=16, m=16, n=4))
    progs, prngs = [], []
    for i in range(3):
        progs.append(eng.lower(spec, jax.random.PRNGKey(i)))
        prngs.append(PRNG.create(spec.tm_config(), 10 + i))
    rng = np.random.default_rng(0)
    x = (rng.random((3, 8, spec.features)) < 0.5).astype(np.int8)
    y = rng.integers(0, spec.classes, (3, 8)).astype(np.int32)
    lits = jnp.stack([eng.encode(spec, jnp.asarray(x[k]))
                      for k in range(3)])
    bank = api.stack(progs, eng, prngs=prngs)
    bank.train(lits, jnp.asarray(y))
    assert eng.cache_report()["path_per_stage"]["train_bank_ta"] == \
        kops.TA_DENSE
    for k in range(3):
        solo_prog, _, _ = eng.train_step(progs[k], prngs[k], lits[k],
                                         jnp.asarray(y[k]))
        got = bank.swap_out(k)
        np.testing.assert_array_equal(np.asarray(got.ta),
                                      np.asarray(solo_prog.ta))
        np.testing.assert_array_equal(np.asarray(got.inc),
                                      np.asarray(solo_prog.inc))


def test_server_surfaces_per_tenant_skip_frac(monkeypatch):
    monkeypatch.setenv("REPRO_SKIP", "1")
    from repro.launch.serve_tm import TMServer
    spec = SPECS["cotm"]
    eng = api.compile(api.tile_for(spec, x=32, y=16, m=16, n=4))
    server = TMServer(eng, batch_slot=8)
    server.register("a", spec)
    server.register("b", spec, seed=5)
    rng = np.random.default_rng(0)
    x = (rng.random((8, spec.features)) < 0.5).astype(np.int8)
    y = rng.integers(0, spec.classes, 8).astype(np.int32)
    stats = server.stats()
    assert stats["skip_frac"] == {"a": None, "b": None}
    for _ in range(3):
        server.train("a", x, y)
    frac = server.stats()["skip_frac"]
    assert frac["b"] is None
    assert frac["a"] is not None and 0.0 <= frac["a"] <= 1.0


def test_resolve_skip_env(monkeypatch):
    for v, want in (("", True), ("auto", True), ("1", True), ("0", False),
                    ("off", False)):
        monkeypatch.setenv("REPRO_SKIP", v)
        assert resolve_skip() is want
    monkeypatch.setenv("REPRO_SKIP", "banana")
    with pytest.raises(ValueError):
        resolve_skip()
    monkeypatch.setenv("REPRO_SKIP", "1")
    assert select_ta_path() == kops.TA_COMPACT
    assert select_ta_path(lanes=4) == kops.TA_DENSE
    monkeypatch.setenv("REPRO_SKIP", "0")
    assert select_ta_path() == kops.TA_DENSE
