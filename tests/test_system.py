"""End-to-end behaviour tests for the DTM system (paper-level claims).

These validate the *relative* paper claims on synthetic surrogates
(DESIGN.md §6): both TM types learn; sequential (paper-faithful) and
batched (scale) modes converge; LFSR-backend training works; the clause-
skip statistic grows as the model converges (Fig 7 mechanism).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import TM, TMSpec
from repro.core import (COALESCED, TMConfig, VANILLA, accuracy,
                        feedback_fit, to_literals)
from repro.core.clause import predict as core_predict
from repro.data import make_bool_dataset, BoolTaskSpec

# Multi-epoch training on synthetic data — nightly tier (ci.yml); the fast
# tier-1 subset runs with -m "not slow".
pytestmark = pytest.mark.slow

SPEC = BoolTaskSpec("test", features=64, classes=4, motifs_per_class=4,
                    motif_bits=8, active_motifs=2, background_p=0.03,
                    flip_p=0.02, seed=99)


def _data(n=768):
    x, y = make_bool_dataset(SPEC, n)
    return x[:512], y[:512], x[512:], y[512:]


@pytest.mark.parametrize("tm_type", [COALESCED, VANILLA])
def test_tm_learns_engine(tm_type):
    """Batched (scale) mode: the unified estimator on the DTM engine."""
    xtr, ytr, xte, yte = _data()
    ctor = TMSpec.coalesced if tm_type == COALESCED else TMSpec.vanilla
    spec = ctor(features=SPEC.features, classes=SPEC.classes, clauses=32,
                T=16, s=4.0, prng_backend="threefry")
    tm = TM(spec, seed=0)
    tm.fit(xtr, ytr, epochs=3, batch=32)
    acc = tm.score(xte, yte)
    assert acc > 0.85, (tm_type, acc)


@pytest.mark.parametrize("tm_type", [COALESCED, VANILLA])
def test_tm_learns_sequential(tm_type):
    """Paper-faithful sequential mode (Fig 9c) on the functional core —
    the reference path the batched-delta engine does not model."""
    xtr, ytr, xte, yte = _data()
    cfg = TMConfig(tm_type=tm_type, features=SPEC.features, clauses=32,
                   classes=SPEC.classes, T=16, s=4.0,
                   prng_backend="threefry")
    state, _, _ = feedback_fit(cfg, xtr, ytr, epochs=3, batch=32, seed=0,
                               mode="sequential")
    acc = accuracy(lambda xb: core_predict(cfg, state, to_literals(xb)),
                   xte, yte)
    assert acc > 0.85, (tm_type, acc)


def test_lfsr_backend_learns():
    xtr, ytr, xte, yte = _data()
    spec = TMSpec.coalesced(features=SPEC.features, classes=SPEC.classes,
                            clauses=32, T=16, s=4.0, prng_backend="lfsr",
                            lfsr_bits=16, seed_refresh=True)
    tm = TM(spec, seed=0)
    tm.fit(xtr, ytr, epochs=2, batch=32)
    assert tm.score(xte, yte) > 0.8


def test_clause_skip_grows_with_convergence():
    """Fig 7 mechanism: feedback (and thus group activity) shrinks as the
    model converges, so skippable group fraction rises."""
    xtr, ytr, _, _ = _data()
    cfg = TMConfig(tm_type=COALESCED, features=SPEC.features, clauses=64,
                   classes=SPEC.classes, T=16, s=4.0,
                   prng_backend="threefry")
    _, _, hist = feedback_fit(cfg, xtr, ytr, epochs=6, batch=64, seed=0,
                              mode="sequential")
    first, last = hist[0], hist[-1]
    assert last["selected_clauses"] < first["selected_clauses"]
    assert last["group_skip_frac"] >= first["group_skip_frac"]


def test_weight_bits_matter():
    """Fig 14 mechanism: very low weight precision hurts accuracy."""
    xtr, ytr, xte, yte = _data()

    def run(bits):
        spec = TMSpec.coalesced(features=SPEC.features,
                                classes=SPEC.classes, clauses=32, T=64,
                                s=4.0, weight_bits=bits,
                                prng_backend="threefry")
        tm = TM(spec, seed=0)
        tm.fit(xtr, ytr, epochs=3, batch=32)
        return tm.score(xte, yte)

    assert run(12) >= run(2) - 0.05  # low precision no better than 12-bit


def test_tm_head_on_backbone_features():
    """DESIGN.md §5: CoTM readout over float backbone features."""
    from repro.core import TMHead
    rng = np.random.default_rng(0)
    protos = rng.standard_normal((3, 16))
    y = rng.integers(0, 3, 512).astype(np.int32)
    feats = protos[y] + 0.3 * rng.standard_normal((512, 16))
    head = TMHead.create(16, 3, calib=feats[:128], therm_bits=4, clauses=32,
                         T=16, s=4.0)
    for ep in range(3):
        for i in range(0, 384, 32):
            head.train_batch(jnp.asarray(feats[i:i + 32], jnp.float32),
                             jnp.asarray(y[i:i + 32]))
    pred = np.asarray(head.predict(jnp.asarray(feats[384:], jnp.float32)))
    assert (pred == y[384:]).mean() > 0.85
