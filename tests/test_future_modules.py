"""Paper §VI future-work modules: Convolutional TM and Regression TM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Every test here trains a TM variant for multiple epochs (2-5 s each) —
# nightly tier; tier-1 runs -m "not slow".
pytestmark = pytest.mark.slow

from repro.core import to_literals
from repro.core.conv_tm import (ConvTMConfig, init as conv_init,
                                predict as conv_predict,
                                train_step as conv_step)
from repro.core.regression_tm import (RegressionTMConfig, init as rtm_init,
                                      predict as rtm_predict,
                                      train_step as rtm_step)


def _translated_motifs(n, seed=0):
    rng = np.random.default_rng(seed)
    motifs = np.array([
        [[1, 1, 1], [0, 0, 0], [1, 1, 1]],
        [[1, 0, 1], [1, 0, 1], [1, 0, 1]],
        [[0, 1, 0], [1, 1, 1], [0, 1, 0]],
    ], np.int8)
    y = rng.integers(0, 3, n).astype(np.int32)
    x = (rng.random((n, 8, 8)) < 0.05).astype(np.int8)
    for i in range(n):
        r, c = rng.integers(0, 6, 2)
        x[i, r:r + 3, c:c + 3] = motifs[y[i]]
    return x, y


def test_conv_tm_position_invariance():
    """ConvTM classifies motifs at RANDOM positions (flat TMs cannot —
    measured gap > 0.4; see benchmarks/convtm_bench.py)."""
    cfg = ConvTMConfig(img_h=8, img_w=8, patch=3, clauses=48, classes=3,
                       T=12, s=3.0)
    state, prng = conv_init(cfg, jax.random.PRNGKey(0))
    x, y = _translated_motifs(640)
    xtr, ytr, xte, yte = x[:512], y[:512], x[512:], y[512:]
    step = jax.jit(lambda s, p, im, lb: conv_step(cfg, s, p, im, lb))
    for ep in range(4):
        for i in range(0, 512, 32):
            state, prng, _ = step(state, prng, jnp.asarray(xtr[i:i + 32]),
                                  jnp.asarray(ytr[i:i + 32]))
    pred = np.asarray(conv_predict(cfg, state, jnp.asarray(xte)))
    assert (pred == yte).mean() > 0.85


def test_conv_tm_state_bounds():
    cfg = ConvTMConfig(img_h=6, img_w=6, patch=3, clauses=16, classes=2,
                       T=8, s=3.0)
    state, prng = conv_init(cfg, jax.random.PRNGKey(0))
    x, y = _translated_motifs(32)
    x = x[:, :6, :6]
    state, prng, _ = conv_step(cfg, state, prng, jnp.asarray(x),
                               jnp.asarray(y % 2))
    ta = np.asarray(state.ta)
    assert ta.min() >= 0 and ta.max() <= cfg.tm_config().n_states - 1


def test_regression_tm_learns_boolean_function():
    rng = np.random.default_rng(0)
    f = 12
    x = (rng.random((1024, f)) < 0.5).astype(np.int8)
    y = (0.6 * x[:, 0] + 0.3 * (x[:, 1] & x[:, 2])
         + 0.1 * x[:, 3]).astype(np.float32)
    xtr, ytr, xte, yte = x[:768], y[:768], x[768:], y[768:]
    cfg = RegressionTMConfig(features=f, clauses=128, T=128, s=3.0)
    state, prng = rtm_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda s, p, l, t: rtm_step(cfg, s, p, l, t))
    for ep in range(10):
        for i in range(0, 768, 32):
            state, prng, _ = step(state, prng,
                                  to_literals(jnp.asarray(xtr[i:i + 32])),
                                  jnp.asarray(ytr[i:i + 32]))
    pred = np.asarray(rtm_predict(cfg, state,
                                  to_literals(jnp.asarray(xte))))
    mae = np.abs(pred - yte).mean()
    base = np.abs(yte.mean() - yte).mean()
    assert mae < base * 0.8, (mae, base)


def test_regression_tm_prediction_range():
    cfg = RegressionTMConfig(features=8, clauses=32, T=32)
    state, prng = rtm_init(cfg, jax.random.PRNGKey(0))
    lits = to_literals(jnp.ones((4, 8), jnp.int8))
    p = np.asarray(rtm_predict(cfg, state, lits))
    assert (p >= 0).all() and (p <= 1).all()
