"""Bit-packed canonical datapath (ISSUE 3 acceptance).

Covers the packed layout end-to-end:

* ``pack_literals`` / ``unpack_literals`` round-trip (hypothesis property
  when available + a deterministic sweep), padded tail words zero, and the
  kernels-side ``ref.pack_bitplane`` pinned bit-for-bit to the core packer;
* ragged-W tail-bit regression: garbage bits past 2f in the last include
  word must never veto a clause (``n_bits`` masking, kernel and ref);
* ops-level parity: ``packed_step_op`` == ``fused_step_op`` on packed
  views of the same problem, remainder shapes included;
* engine-level parity: all FIVE TM variants forced onto the packed path
  (``REPRO_KERNEL_PATH=packed_vpu``) reproduce the auto-dispatch results
  bit-for-bit on BOTH backends, with every stage executable still at one
  jit cache entry and ``path_per_stage`` proving dispatch == execution;
* the packed program payload: uint8 TA + uint32 include bitplane, include
  maintained incrementally by the train stages (never re-thresholded).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import TMSpec
from repro.core import PRNG
from repro.core.booleanize import pack_literals, unpack_literals
from repro.kernels import (fused_step_op, packed_clause_eval_op,
                           packed_step_op, ref, select_path)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # bare tier-1 env
    hypothesis = None

_rng = np.random.default_rng(42)
_CALIB = _rng.standard_normal((64, 8)).astype(np.float32)
BATCH = 8

SPECS = {
    "cotm": TMSpec.coalesced(features=20, classes=3, clauses=24, T=8, s=3.0),
    "vanilla": TMSpec.vanilla(features=16, classes=4, clauses=8, T=8, s=3.0),
    "conv": TMSpec.conv(img_h=6, img_w=6, patch=3, classes=2, clauses=16,
                        T=8, s=3.0),
    "regression": TMSpec.regression(features=12, clauses=16, T=16, s=3.0),
    "head": TMSpec.head(_CALIB, classes=3, therm_bits=2, clauses=16, T=8,
                        s=3.0),
}


# ---------------------------------------------------------------------------
# pack/unpack round-trip
# ---------------------------------------------------------------------------

def _roundtrip(bits: np.ndarray):
    packed = pack_literals(jnp.asarray(bits))
    n = bits.shape[-1]
    W = (n + 31) // 32
    assert packed.dtype == jnp.uint32 and packed.shape[-1] == W
    back = unpack_literals(packed, n)
    np.testing.assert_array_equal(np.asarray(back), bits)
    # padded tail bits of the last word are zero
    full = unpack_literals(packed, 32 * W)
    assert (np.asarray(full)[..., n:] == 0).all()


if hypothesis is not None:
    @given(st.integers(1, 131), st.integers(0, 5), st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_pack_unpack_roundtrip_property(n, b, seed):
        rng = np.random.default_rng(seed)
        shape = (b, n) if b else (n,)
        _roundtrip((rng.random(shape) < 0.5).astype(np.int8))


def test_pack_unpack_roundtrip_sweep():
    """Deterministic fallback sweep (always runs, hypothesis or not)."""
    rng = np.random.default_rng(0)
    for n in (1, 31, 32, 33, 64, 100, 127, 128):
        _roundtrip((rng.random((3, n)) < 0.5).astype(np.int8))


def test_ref_pack_bitplane_matches_core_packer():
    """kernels.ref keeps a local copy of the packer (import isolation);
    the two layouts must stay bit-for-bit identical."""
    rng = np.random.default_rng(1)
    bits = (rng.random((5, 77)) < 0.5).astype(np.int8)
    np.testing.assert_array_equal(
        np.asarray(pack_literals(jnp.asarray(bits))),
        np.asarray(ref.pack_bitplane(jnp.asarray(bits))))


def test_pack_include_thresholds_and_packs():
    rng = np.random.default_rng(2)
    ta = jnp.asarray(rng.integers(0, 256, (6, 70)).astype(np.int32))
    inc = ref.pack_include(ta, 256)
    want = pack_literals((np.asarray(ta) >= 128).astype(np.int8))
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(want))


# ---------------------------------------------------------------------------
# ragged-W tail bits (satellite: garbage past 2f must not veto)
# ---------------------------------------------------------------------------

def test_tail_mask_words():
    w = jnp.full((2, 3), 0xFFFFFFFF, jnp.uint32)
    got = np.asarray(ref.tail_mask_words(w, 70))        # 70 = 2*32 + 6
    assert (got[:, :2] == 0xFFFFFFFF).all()
    assert (got[:, 2] == 0x3F).all()
    np.testing.assert_array_equal(
        np.asarray(ref.tail_mask_words(w, 96)), np.asarray(w))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("eval_mode", [False, True])
def test_ragged_tail_bits_never_veto(backend, eval_mode):
    """Regression: poison every bit past 2f in the last include word; with
    ``n_bits`` the clause outputs must equal the dense oracle anyway."""
    rng = np.random.default_rng(3)
    B, C, L = 4, 8, 100                                  # W=4, 28 tail bits
    lit = (rng.random((B, L)) < 0.5).astype(np.int8)
    inc = (rng.random((C, L)) < 0.1).astype(np.int8)
    inc[1] = 0                                           # an empty clause
    pl, pi = pack_literals(jnp.asarray(lit)), pack_literals(jnp.asarray(inc))
    tail = jnp.uint32(0xFFFFFFFF ^ ((1 << (L % 32)) - 1))
    pi_poison = pi.at[:, -1].set(pi[:, -1] | tail)
    want = ref.clause_eval_ref(jnp.asarray(lit), jnp.asarray(inc),
                               eval_mode=eval_mode)
    got = packed_clause_eval_op(pl, pi_poison, eval_mode=eval_mode,
                                n_bits=L, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # sanity: without masking the poison DOES veto (the bug this guards)
    bad = packed_clause_eval_op(pl, pi_poison, eval_mode=eval_mode,
                                backend=backend)
    assert (np.asarray(bad) == 0).all()


# ---------------------------------------------------------------------------
# ops-level parity: packed train front half == fused kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,R,L,H,n_cl,n_h", [
    (8, 128, 256, 8, 128, 8),      # tile-exact
    (5, 100, 200, 6, 90, 5),       # remainders everywhere, ragged W
    (1, 64, 100, 4, 60, 3),        # edge single datapoint
])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_packed_step_op_matches_fused(B, R, L, H, n_cl, n_h, backend):
    rng = np.random.default_rng(B * 7 + L)
    lit = jnp.asarray((rng.random((B, L)) < 0.5).astype(np.int8))
    inc = jnp.asarray((rng.random((R, L)) < 0.05).astype(np.int8))
    w = jnp.asarray(rng.integers(-15, 16, (H, R)).astype(np.int32))
    lab = jnp.asarray(rng.integers(0, n_h, B).astype(np.int32))
    neg = jnp.asarray((lab + 1) % n_h)
    r1 = jnp.asarray(rng.integers(0, 1 << 16, (B, R), dtype=np.uint32))
    r2 = jnp.asarray(rng.integers(0, 1 << 16, (B, R), dtype=np.uint32))
    clm = (jnp.arange(R) < n_cl).astype(jnp.int32)
    hm = (jnp.arange(H) < n_h).astype(jnp.int32)
    T, wf = jnp.asarray(16, jnp.int32), jnp.asarray(0, jnp.int32)
    args = (w, lab, neg, r1, r2, clm, hm, T, wf)
    want = fused_step_op(lit, inc, *args)
    got = packed_step_op(pack_literals(lit), pack_literals(inc), *args,
                         backend=backend, n_bits=L)
    for name, g, wt in zip(("clause", "sums", "sel_lab", "sel_neg"),
                           got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wt),
                                      err_msg=f"{name} [{backend}]")


# ---------------------------------------------------------------------------
# engine-level: five variants on the packed path, bit-identical
# ---------------------------------------------------------------------------

def _batch(spec: TMSpec, seed: int = 5, batch: int = BATCH):
    rng = np.random.default_rng(seed)
    cfg = spec.tm_config()
    if spec.kind == "conv":
        x = (rng.random((batch, 6, 6)) < 0.3).astype(np.int8)
        y = rng.integers(0, 2, batch).astype(np.int32)
    elif spec.kind == "head":
        x = rng.standard_normal((batch, 8)).astype(np.float32)
        y = rng.integers(0, 3, batch).astype(np.int32)
    elif spec.kind == "regression":
        x = (rng.random((batch, 12)) < 0.5).astype(np.int8)
        y = np.round(rng.random(batch) * cfg.T).astype(np.int32)
    else:
        x = (rng.random((batch, cfg.features)) < 0.5).astype(np.int8)
        y = rng.integers(0, cfg.classes, batch).astype(np.int32)
    return x, y


def _roster(backend: str):
    tile = api.tile_for(*SPECS.values(), x=32, y=16, m=16, n=4)
    eng = api.compile(tile, backend=backend)
    out = {}
    for name, spec in SPECS.items():
        x, y = _batch(spec)
        prog = eng.lower(spec, jax.random.PRNGKey(0))
        lits = eng.encode(spec, jnp.asarray(x))
        step = eng.train_conv if spec.kind == "conv" else eng.train_step
        infer = eng.infer_conv if spec.kind == "conv" else eng.infer
        new_prog, _, stats = step(prog, PRNG.create(spec.tm_config(), 7),
                                  lits, jnp.asarray(y))
        sums, cl = infer(prog, lits)
        out[name] = {"ta": np.asarray(new_prog.ta),
                     "inc": np.asarray(new_prog.inc),
                     "weights": np.asarray(new_prog.weights),
                     "sums": np.asarray(sums), "cl": np.asarray(cl),
                     "stats": {k: int(v) for k, v in stats.items()}}
    return out, eng


@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_five_variants_packed_path_bit_identical(backend, monkeypatch):
    """Acceptance: packed and unpacked paths agree bit-for-bit on all five
    TM variants, infer AND train, on this backend; cache stays at one
    entry per stage and every stage reports packed execution."""
    monkeypatch.delenv("REPRO_KERNEL_PATH", raising=False)
    base, _ = _roster(backend)
    monkeypatch.setenv("REPRO_KERNEL_PATH", "packed_vpu")
    packed, eng = _roster(backend)
    report = eng.cache_report()
    for stage in ("infer", "train", "infer_conv", "train_conv"):
        assert report[stage] == 1, report
        assert report["path_per_stage"][stage] == "packed_vpu", report
    for name in SPECS:
        for k in ("ta", "inc", "weights", "sums", "cl"):
            np.testing.assert_array_equal(base[name][k], packed[name][k],
                                          err_msg=f"{name}/{k}")
        assert base[name]["stats"] == packed[name]["stats"], name


@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_edge_batch_defaults_to_packed_dispatch(backend, monkeypatch):
    """B=1 (the FPGA edge regime) resolves to the packed path without any
    env force, and the engine records dispatch == execution."""
    monkeypatch.delenv("REPRO_KERNEL_PATH", raising=False)
    spec = SPECS["cotm"]
    eng = api.compile(api.tile_for(spec, x=32, y=16, m=16, n=4),
                      backend=backend)
    prog = eng.lower(spec, jax.random.PRNGKey(0))
    x, y = _batch(spec, batch=1)
    lits = eng.encode(spec, jnp.asarray(x))
    assert lits.dtype == jnp.uint32 and lits.shape == (1, eng.W)
    eng.infer(prog, lits)
    eng.train_step(prog, PRNG.create(spec.tm_config(), 7), lits,
                   jnp.asarray(y))
    paths = eng.cache_report()["path_per_stage"]
    assert paths["infer"] == select_path(None, batch=1) == "packed_vpu"
    assert paths["train"] == select_path(None, batch=1,
                                         training=True) == "packed_vpu"


# ---------------------------------------------------------------------------
# packed program payload + incremental include maintenance
# ---------------------------------------------------------------------------

def test_program_payload_is_packed():
    """uint8 TA (4 states/word) + uint32 include bitplane: the hot-swap
    payload for TA+include shrinks >= 6x vs the int32 pair it replaces."""
    spec = SPECS["cotm"]
    eng = api.compile(api.tile_for(spec, x=32, y=16, m=16, n=4),
                      backend="ref")
    prog = eng.lower(spec, jax.random.PRNGKey(0))
    assert prog.ta.dtype == jnp.uint8
    assert prog.inc.dtype == jnp.uint32
    assert prog.inc.shape == (eng.R, eng.W) and eng.W == (eng.L + 31) // 32
    packed_bytes = prog.ta.nbytes + prog.inc.nbytes
    unpacked_bytes = 2 * (eng.R * eng.L * 4)       # int32 ta + int32 include
    assert unpacked_bytes >= 6 * packed_bytes, (unpacked_bytes, packed_bytes)


@pytest.mark.parametrize("kind", ["cotm", "conv"])
def test_include_bitplane_maintained_incrementally(kind):
    """After any train step the program's inc equals the bitplane of its
    updated TA — the update stage emitted it; nothing re-thresholds."""
    spec = SPECS[kind]
    tile = api.tile_for(*SPECS.values(), x=32, y=16, m=16, n=4)
    eng = api.compile(tile, backend="ref")
    prog = eng.lower(spec, jax.random.PRNGKey(0))
    prng = PRNG.create(spec.tm_config(), 7)
    step = eng.train_conv if kind == "conv" else eng.train_step
    for i in range(3):
        x, y = _batch(spec, seed=i)
        lits = eng.encode(spec, jnp.asarray(x))
        prog, prng, _ = step(prog, prng, lits, jnp.asarray(y))
        want = ref.pack_include(prog.ta.astype(jnp.int32), prog.n_states)
        np.testing.assert_array_equal(np.asarray(prog.inc),
                                      np.asarray(want))


def test_save_load_rebuilds_include(tmp_path):
    """TM.load replaces TA wholesale from the checkpoint; the engine must
    rebuild the bitplane so packed inference matches exactly."""
    from repro.api import TM
    spec = SPECS["cotm"]
    tm = TM(spec, tile=api.tile_for(spec, x=32, y=16, m=16, n=4),
            backend="ref", seed=0)
    x, y = _batch(spec)
    tm.partial_fit(x, y)
    tm.save(str(tmp_path))
    tm2 = TM.load(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(tm.program.inc),
                                  np.asarray(tm2.program.inc))
    np.testing.assert_array_equal(np.asarray(tm.predict(x[:1])),
                                  np.asarray(tm2.predict(x[:1])))
