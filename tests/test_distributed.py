"""Distributed-runtime tests — run in subprocesses with forced host device
counts (the main pytest process keeps the default 1 device, per the
dry-run's isolation requirement)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_tm_dp_equals_local_batched():
    """DP psum of integer deltas == single-device batched mode, exactly."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import TMConfig, init_state, COALESCED, to_literals
        from repro.core import feedback
        from repro.core.distributed import dp_train_step, _shard_prng
        cfg = TMConfig(tm_type=COALESCED, features=24, clauses=16, classes=3,
                       T=8, s=3.0, prng_backend="threefry")
        state = init_state(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray((rng.random((16, 24)) < 0.4).astype(np.int8))
        y = jnp.asarray(rng.integers(0, 3, 16).astype(np.int32))
        lits = to_literals(x)
        mesh = jax.make_mesh((8,), ("data",))
        dp_state, _ = dp_train_step(cfg, state, lits, y, mesh, seed=5, chunk=2)
        # local replay: same per-shard streams, summed deltas
        acc_ta = jnp.zeros_like(state.ta)
        acc_w = jnp.zeros_like(state.weights)
        for i in range(8):
            prng = _shard_prng(cfg, 5, jnp.uint32(i))
            _, d_ta, d_w, _, _ = feedback.batched_deltas(
                cfg, state, prng, lits[i*2:(i+1)*2], y[i*2:(i+1)*2], 2)
            acc_ta += d_ta; acc_w += d_w
        ref_state, _ = feedback.apply_deltas(cfg, state, acc_ta, acc_w,
                                             jnp.zeros((16,), jnp.int32),
                                             jnp.int32(0))
        assert (np.asarray(dp_state.ta) == np.asarray(ref_state.ta)).all()
        assert (np.asarray(dp_state.weights) == np.asarray(ref_state.weights)).all()
        print("EXACT")
    """)


@pytest.mark.slow
def test_compressed_psum_shardmap():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.runtime.compression import compressed_psum
        # the version-portable wrapper distributed.py resolves ONCE
        from repro.core.distributed import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)),
                        jnp.float32)
        def f(xl):
            y, resid = compressed_psum(xl, "data")
            return y, resid
        g = shard_map(f, mesh, in_specs=(P("data"),),
                      out_specs=(P("data"), P("data")))
        y, resid = g(x)
        want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 128))
        got = np.asarray(y)
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.1, rel          # int8 quantisation error bound
        assert np.abs(np.asarray(resid)).max() > 0   # error feedback active
        print("REL", rel)
    """)


@pytest.mark.slow
def test_dp_wire_compaction_exact():
    """Alg-6 WIRE compaction of the TA-delta psum (ISSUE 5): with
    compact_frac set, only the union of active rows crosses the wire —
    bit-exact vs the dense all-reduce, both when the union fits the
    capacity and when it overflows to the dense fallback.  The bucket
    predicate comes from the psum'd bitmap, so all shards take the same
    lax.cond branch (matched collectives)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import TMConfig, init_state, COALESCED, to_literals
        from repro.core.distributed import dp_train_step
        cfg = TMConfig(tm_type=COALESCED, features=24, clauses=64, classes=3,
                       T=8, s=3.0, prng_backend="threefry")
        state = init_state(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray((rng.random((8, 24)) < 0.4).astype(np.int8))
        y = jnp.asarray(rng.integers(0, 3, 8).astype(np.int32))
        lits = to_literals(x)
        mesh = jax.make_mesh((4,), ("data",))
        dense, _ = dp_train_step(cfg, state, lits, y, mesh, seed=5, chunk=2)
        # roomy capacity: the compact branch carries the deltas
        comp, _ = dp_train_step(cfg, state, lits, y, mesh, seed=5, chunk=2,
                                compact_frac=0.5)
        # tiny capacity: overflow -> dense fallback branch, still exact
        tiny, _ = dp_train_step(cfg, state, lits, y, mesh, seed=5, chunk=2,
                                compact_frac=0.02)
        for got in (comp, tiny):
            assert (np.asarray(dense.ta) == np.asarray(got.ta)).all()
            assert (np.asarray(dense.weights)
                    == np.asarray(got.weights)).all()
        print("EXACT")
    """, devices=4)


# NOTE: the seed-era Supervisor/shrink_mesh elastic-restart test was
# retired with the runtime/fault.py rewrite (ISSUE 10) — crash recovery
# for the DTM serving stack (the thing this repo actually ships) is
# covered by tests/test_recovery.py, including its @needs_mesh leg.


@pytest.mark.slow
def test_tm_pod_step_and_alg6_compaction_exact():
    """Pod-scale CoTM step (clause×batch sharding) + Alg-6 feedback
    compaction: bit-exact vs the dense path when K >= #selected/shard."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import TMConfig, init_state, COALESCED, to_literals
        from repro.core.distributed import pod_train_step
        cfg = TMConfig(tm_type=COALESCED, features=24, clauses=32, classes=4,
                       T=8, s=3.0, prng_backend="counter")
        state = init_state(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        lits = to_literals(jnp.asarray((rng.random((16, 24)) < 0.4
                                        ).astype(np.int8)))
        y = jnp.asarray(rng.integers(0, 4, 16).astype(np.int32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        s_dense, st = pod_train_step(cfg, state, lits, y, mesh, seed=3)
        s_comp, _ = pod_train_step(cfg, state, lits, y, mesh, seed=3,
                                   compact_k=8)
        assert (np.asarray(s_dense.ta) == np.asarray(s_comp.ta)).all()
        assert (np.asarray(s_dense.weights) ==
                np.asarray(s_comp.weights)).all()
        assert not (np.asarray(s_dense.ta) == np.asarray(state.ta)).all()
        print("POD+ALG6 EXACT", int(st["selected"]))
    """)
